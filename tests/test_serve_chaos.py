"""Chaos: seeded fault-injection storms against the resident study server.

Every injected fault class must resolve to exactly one of {reject,
retry-success, degrade, timeout, clean restart} — never a wrong result,
never a silent drop.  Storms are bit-reproducible per seed (Threefry
oracle), so the CI matrix re-runs the same storms on every platform; set
``REPRO_CHAOS_SEED`` to pin a single seed (the CI fault-injection legs
do), otherwise all default seeds run."""

import os

import numpy as np
import pytest

from repro.serve import (
    CRASHED,
    OK,
    OK_DEGRADED,
    REJECTED_MALFORMED,
    REJECTED_OVERSIZED,
    TIMEOUT,
    ChaosConfig,
    ChaosMonkey,
    ServeConfig,
    StudyServer,
    VirtualClock,
    build_study,
    make_storm,
    restart_server,
)

SMALL = dict(num_kernels=3, windows_per_kernel=2)
BASE_SPECS = [
    {"workloads": [{"app": "pagerank", "graph": "arxiv", "scale": 0.4,
                    **SMALL}],
     "mechanisms": ["cpu", "lazypim"], "threads": 16},
    {"workloads": [{"app": "htap128", "scale": 0.004, **SMALL}],
     "mechanisms": ["cpu", "lazypim"], "threads": 16},
]

SEEDS = ([int(os.environ["REPRO_CHAOS_SEED"])]
         if "REPRO_CHAOS_SEED" in os.environ else [0, 1, 2])


def _reference_rows(rid):
    """Fault-free sequential-engine answer for the storm's rid-th request."""
    return build_study(BASE_SPECS[rid % len(BASE_SPECS)]) \
        .run("sequential").to_rows()


def _assert_right_answer(resp):
    """A served response (degraded or not, replayed or not) must be
    bit-exact with the fault-free sequential reference."""
    got = resp.results.to_rows()
    want = _reference_rows(resp.rid)
    assert len(got) == len(want)
    for x, y in zip(got, want):
        for k in x:
            if isinstance(x[k], float):
                np.testing.assert_array_equal(x[k], y[k])
            else:
                assert x[k] == y[k]


def _run_storm(seed, classes, n=16, fault_rate=0.6):
    clock = VirtualClock()
    monkey = ChaosMonkey(
        ChaosConfig(seed=seed, fault_rate=fault_rate, classes=classes,
                    hang_s=60.0),
        clock=clock)
    # Deadlines are effectively infinite so one request's 60s hang doesn't
    # eat the deadline budget of everything queued behind it (the hang
    # itself is caught by the heartbeat monitor, not the deadline; deadline
    # expiry has its own test in test_serve.py).
    cfg = ServeConfig(default_deadline_s=1e9, heartbeat_timeout_s=20.0,
                      backoff_base_s=0.01, max_queue=n, max_lanes=64)
    srv = StudyServer(cfg, clock=clock, chaos=monkey)
    for spec in make_storm(monkey, n, BASE_SPECS):
        srv.submit(spec)
    srv.drain()
    return srv, monkey, clock


EXPECT = {
    None: OK,
    "malformed_spec": REJECTED_MALFORMED,
    "oversized": REJECTED_OVERSIZED,
    "hang": TIMEOUT,
}


@pytest.mark.parametrize("seed", SEEDS)
def test_every_fault_class_resolves_as_required(seed):
    classes = ("malformed_spec", "oversized", "engine_exception", "hang")
    srv, monkey, _ = _run_storm(seed, classes)
    assert len(srv.responses) == 16  # one terminal response per request
    for rid, resp in srv.responses.items():
        kind = monkey.fault_for(rid)
        if kind == "engine_exception":
            if monkey.is_transient(rid):
                assert resp.status == OK and resp.attempts == 2, rid
            else:
                assert resp.status == OK_DEGRADED, rid
                assert resp.engine == "sequential"
        else:
            assert resp.status == EXPECT[kind], (rid, kind)
        if resp.served:
            _assert_right_answer(resp)  # zero wrong results, ever
    # The storm actually exercised multiple fault classes at this seed.
    hit = {monkey.fault_for(r) for r in range(16)} - {None}
    assert len(hit) >= 3, f"seed {seed} storm too quiet: {hit}"


@pytest.mark.parametrize("seed", SEEDS)
def test_storm_is_bit_reproducible(seed):
    classes = ("malformed_spec", "oversized", "engine_exception", "hang")
    a, _, ca = _run_storm(seed, classes)
    b, _, cb = _run_storm(seed, classes)
    assert {r: v.status for r, v in a.responses.items()} == \
        {r: v.status for r, v in b.responses.items()}
    assert {r: v.attempts for r, v in a.responses.items()} == \
        {r: v.attempts for r, v in b.responses.items()}
    assert ca.slept == cb.slept  # identical backoff + hang timeline


@pytest.mark.parametrize("seed", SEEDS)
def test_crash_storm_recovers_with_no_silent_drops(seed, tmp_path):
    n = 12
    clock = VirtualClock()
    monkey = ChaosMonkey(
        ChaosConfig(seed=seed, fault_rate=0.6, hang_s=60.0), clock=clock)
    cfg = ServeConfig(default_deadline_s=1e9, heartbeat_timeout_s=20.0,
                      backoff_base_s=0.01, max_queue=n, max_lanes=64,
                      cache_dir=str(tmp_path / f"seed{seed}"))
    srv = StudyServer(cfg, clock=clock, chaos=monkey)
    final = {}
    for spec in make_storm(monkey, n, BASE_SPECS):
        out = srv.submit(spec)
        if not isinstance(out, int):
            final[out.rid] = out  # admission reject is already terminal
    for r in srv.drain():
        final[r.rid] = r

    restarts = 0
    while srv.crashed:
        restarts += 1
        assert restarts <= n, "restart loop did not converge"
        srv, replayed = restart_server(cfg, clock=clock, chaos=monkey)
        for r in replayed:
            assert r.restarted
            final[r.rid] = r
        for r in srv.drain():
            final[r.rid] = r

    # Exactly one terminal, non-crashed response per request — a crash is
    # never an answer, only a handoff to the restarted server.
    assert sorted(final) == list(range(n))
    assert all(r.status != CRASHED for r in final.values())
    crashed_rids = [rid for rid in range(n)
                    if monkey.fault_for(rid) == "crash"]
    if crashed_rids:
        assert restarts >= 1
        for rid in crashed_rids:
            assert final[rid].status == OK and final[rid].restarted, rid
    for r in final.values():
        if r.served:
            _assert_right_answer(r)
