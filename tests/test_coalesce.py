"""Fault-isolated cross-request lane coalescing: bit-exactness vs the
one-at-a-time loop, masked pad lanes, bisection isolation of poison
requests (chaos storms over >= 3 seeds), per-lane integrity sentinels,
audit-mismatch degradation, blessed-width warm/compile-key reuse, and the
PR-7 hardening satellites (deadline-at-admission shed, manifest/journal
quarantine-and-rebuild, ResultSet schema errors).

Set ``REPRO_CHAOS_SEED`` to pin a single seed (the CI fault-injection
legs run one seed per matrix entry).
"""

import json
import os

import numpy as np
import pytest

from repro.serve import (
    BLESSED_LANE_WIDTHS,
    OK,
    OK_DEGRADED,
    QUARANTINED,
    SERVED,
    BoundedQueue,
    ChaosConfig,
    ChaosMonkey,
    InjectedEngineError,
    ServeConfig,
    StudyServer,
    VirtualClock,
    audit_sample,
    blessed_width,
    build_study,
    group_key,
    restart_server,
)
from repro.sim import engine as _engine
from repro.sim.study import ResultSet, ResultSetSchemaError

SEEDS = ([int(os.environ["REPRO_CHAOS_SEED"])]
         if "REPRO_CHAOS_SEED" in os.environ else [0, 1, 2])

SMALL = dict(num_kernels=3, windows_per_kernel=2)
SPEC_A = {
    "workloads": [{"app": "pagerank", "graph": "arxiv", "scale": 0.4,
                   **SMALL}],
    "mechanisms": ["cpu", "lazypim"],
    "threads": 16,
}
SPEC_B = {
    "workloads": [{"app": "htap128", "scale": 0.004, **SMALL}],
    "mechanisms": ["cpu", "lazypim"],
    "threads": 16,
}
# Same geometry as SPEC_A but a 2-point hw axis: coalesces with it.
SPEC_A2 = {**SPEC_A, "hw_grid": {"offchip_bw_gbs": [16.0, 32.0]}}


def _server(clock=None, chaos=None, **cfg_kw):
    cfg_kw.setdefault("default_deadline_s", 1e9)
    cfg_kw.setdefault("coalesce", True)
    return StudyServer(ServeConfig(**cfg_kw), clock=clock or VirtualClock(),
                       chaos=chaos)


def _assert_rows_equal(a, b):
    ra, rb = a.to_rows(), b.to_rows()
    assert len(ra) == len(rb)
    for x, y in zip(ra, rb):
        assert x.keys() == y.keys()
        for k in x:
            if isinstance(x[k], float):
                np.testing.assert_array_equal(x[k], y[k]), k
            else:
                assert x[k] == y[k], k


# -- pure mechanics ----------------------------------------------------------


def test_blessed_width_rounds_up_to_pow2():
    assert [blessed_width(n) for n in (1, 2, 3, 4, 5, 8, 9, 64)] == \
        [1, 2, 4, 4, 8, 8, 16, 64]
    with pytest.raises(ValueError):
        blessed_width(0)
    with pytest.raises(ValueError):
        blessed_width(BLESSED_LANE_WIDTHS[-1] + 1)


def test_audit_sample_is_deterministic_and_bounded():
    s1 = audit_sample(0, 7, 16, 0.25)
    s2 = audit_sample(0, 7, 16, 0.25)
    assert s1 == s2 and len(s1) == 4
    assert all(0 <= i < 16 for i in s1) and sorted(set(s1)) == s1
    assert audit_sample(0, 8, 16, 0.25) != s1  # per-dispatch stream
    assert audit_sample(0, 7, 16, 0.0) == []
    assert audit_sample(0, 7, 5, 1.0) == [0, 1, 2, 3, 4]
    assert len(audit_sample(0, 7, 16, 0.01)) == 1  # at least one lane


def test_queue_take_removes_matches_preserving_order():
    q = BoundedQueue(8)
    for x in (1, 2, 3, 4, 5):
        q.offer(x)
    assert q.take(lambda x: x % 2 == 0) == [2, 4]
    assert [q.pop(), q.pop(), q.pop()] == [1, 3, 5]
    assert q.pop() is None


def test_group_key_compatibility():
    ka = group_key(build_study(SPEC_A))
    ka2 = group_key(build_study(SPEC_A2))
    kb = group_key(build_study(SPEC_B))
    assert ka is not None and ka == ka2  # hw axis is per-lane data
    assert ka != kb                      # different geometry bucket
    multi = build_study({**SPEC_A, "workloads": [
        SPEC_A["workloads"][0],
        {"app": "pagerank", "graph": "arxiv", "scale": 0.4,
         "num_kernels": 3, "windows_per_kernel": 40}]})
    if len(multi.bucket_lanes()) > 1:  # windows differ but bucket may merge
        assert group_key(multi) is None


# -- bit-exactness and pad-lane masking --------------------------------------


def test_coalesced_bit_exact_vs_one_at_a_time():
    specs = [SPEC_A, SPEC_B, SPEC_A2, SPEC_A, SPEC_B, SPEC_A, SPEC_A2,
             SPEC_B]  # queue depth 8, three group keys
    co = _server(audit_fraction=1.0)
    for s in specs:
        co.submit(s)
    coalesced = co.drain()

    solo = StudyServer(ServeConfig(default_deadline_s=1e9),
                       clock=VirtualClock())
    for s in specs:
        solo.submit(s)
    baseline = solo.drain()

    assert len(coalesced) == len(baseline) == len(specs)
    assert co.stats["coalesced_dispatches"] >= 1
    # Coalesced drain resolves in group order (the head pulls compatible
    # peers forward), so align by rid — every request must still resolve.
    by_rid = {r.rid: r for r in coalesced}
    assert sorted(by_rid) == sorted(b.rid for b in baseline)
    for b in baseline:
        a = by_rid[b.rid]
        assert a.status == OK and a.engine == "coalesced"
        assert b.status == OK and b.engine == "batch"
        _assert_rows_equal(a.results, b.results)


def test_masked_pad_lanes_never_contribute():
    # Three lanes pad to blessed width 4: one all-sentinel masked lane
    # rides the dispatch.  Every served number must equal the unpadded
    # study run AND the sequential reference, field-exact.
    srv = _server(audit_fraction=0.0)
    for _ in range(3):
        srv.submit(SPEC_A)
    out = srv.drain()
    assert [r.status for r in out] == [OK] * 3
    assert srv.stats["coalesced_dispatches"] == 1
    ref = build_study(SPEC_A).run("sequential")
    for r in out:
        _assert_rows_equal(r.results, ref)


def test_multi_bucket_request_falls_back_to_single_request_path():
    spec = {**SPEC_A, "workloads": [
        {"app": "pagerank", "graph": "arxiv", "scale": 0.4, **SMALL},
        {"app": "pagerank", "graph": "arxiv", "scale": 3.0,
         "num_kernels": 3, "windows_per_kernel": 2}]}
    study = build_study(spec)
    if group_key(study) is not None:
        pytest.skip("scales landed in one geometry bucket")
    srv = _server()
    srv.submit(spec)
    (resp,) = srv.drain()
    assert resp.status == OK and resp.engine == "batch"
    _assert_rows_equal(resp.results, build_study(spec).run("sequential"))


# -- poison isolation (the robustness headline) ------------------------------


def _poison_storm(seed, classes, n=8, fault_rate=0.25, audit=1.0):
    clock = VirtualClock()
    monkey = ChaosMonkey(ChaosConfig(seed=seed, fault_rate=fault_rate,
                                     classes=classes), clock=clock)
    srv = _server(clock=clock, chaos=monkey, audit_fraction=audit,
                  seed=seed)
    for _ in range(n):
        srv.submit(SPEC_A)
    out = srv.drain()
    faults = {rid: monkey.fault_for(rid) for rid in range(n)}
    return srv, out, faults


@pytest.mark.parametrize("seed", SEEDS)
def test_poison_lane_bisection_isolates_exactly_the_poison(seed):
    srv, out, faults = _poison_storm(seed, ("poison_lane",))
    poisoned = {rid for rid, f in faults.items() if f == "poison_lane"}
    assert poisoned, f"seed {seed} drew no poison_lane faults; pick another"
    ref = build_study(SPEC_A).run("sequential")
    for r in out:
        if r.rid in poisoned:
            # The offender is quarantined with its bisection trace...
            assert r.status == QUARANTINED
            assert "bisection" in r.error
            rec = srv.quarantine[r.rid]
            assert rec["spec"] == SPEC_A
            assert any("failed" in ev["outcome"]
                       for ev in rec["bisection"])
            # ...and every failed sub-dispatch in its trace contained it.
            for ev in rec["bisection"]:
                if "failed" in ev["outcome"]:
                    assert set(ev["members"]) & poisoned
        else:
            # Healthy co-batched neighbors are never timed out, degraded
            # away, or corrupted: served ok, bit-exact.
            assert r.status == OK, (r.rid, r.status, r.error)
            _assert_rows_equal(r.results, ref)
    assert set(srv.quarantine) == poisoned


@pytest.mark.parametrize("seed", SEEDS)
def test_poison_result_storm_never_serves_a_wrong_answer(seed):
    srv, out, faults = _poison_storm(seed, ("poison_result",),
                                     fault_rate=0.3)
    poisoned = {rid for rid, f in faults.items() if f == "poison_result"}
    assert poisoned, f"seed {seed} drew no poison_result faults"
    injected = dict(srv.chaos.injected)
    ref = build_study(SPEC_A).run("sequential")
    for r in out:
        kind = injected.get(r.rid)
        if kind == "poison_result:nan":
            # NaN trips the finalize sentinel: lane-exact attribution,
            # no bisection needed, straight to quarantine.
            assert r.status == QUARANTINED
            assert "integrity sentinel" in r.error
            assert r.rid in srv.quarantine
        else:
            # Finite corruption anywhere in the batch is caught by the
            # audit, which degrades the whole sub-batch to the sequential
            # reference — so even the poisoned request's answer is
            # *correct* (recomputed), and healthy members always are.
            assert r.status in SERVED, (r.rid, r.status, r.error)
            _assert_rows_equal(r.results, ref)
    if any(k == "poison_result:finite" for k in injected.values()):
        assert srv.stats["audit_mismatches"] >= 1
        assert any(r.status == OK_DEGRADED for r in out)


def test_poison_result_nan_is_lane_attributed():
    # Seed 2 deterministically draws the NaN variant for rid 2 (and only
    # rid 2) at fault_rate 0.3 — neighbors stay ok on the same dispatch.
    srv, out, faults = _poison_storm(2, ("poison_result",), n=6,
                                     fault_rate=0.3)
    statuses = {r.rid: r.status for r in out}
    assert statuses[2] == QUARANTINED
    assert all(s == OK for rid, s in statuses.items() if rid != 2)
    assert list(srv.quarantine) == [2]


class _SlowFaultMonkey(ChaosMonkey):
    """poison_lane faults that burn virtual wall before dying — the cost a
    real clock sees when a poisoned engine execution fails partway in,
    multiplied across every bisection sub-dispatch containing the poison."""

    def __init__(self, cfg, clock, fault_wall_s):
        super().__init__(cfg, clock=clock)
        self.fault_wall_s = fault_wall_s

    def on_coalesced_dispatch(self, rids, dispatch):
        try:
            super().on_coalesced_dispatch(rids, dispatch)
        except InjectedEngineError:
            self.clock.advance(self.fault_wall_s)
            raise


@pytest.mark.parametrize("seed", SEEDS)
def test_healthy_request_admitted_after_poison_storm(seed):
    # Regression: the admission estimator's service-time EMA must not be
    # poisoned by quarantine/bisection incidents.  The pre-fix step() gate
    # excluded only TIMEOUT/CRASHED, so a quarantine-bearing step fed its
    # fault-handling wall (here 900 s per failed bisection sub-dispatch)
    # into the EMA — inflating it past any default deadline and shedding
    # every later healthy request as overload, permanently: a shed request
    # never runs, so nothing ever corrects the estimate back down.
    clock = VirtualClock()
    monkey = _SlowFaultMonkey(
        ChaosConfig(seed=seed, fault_rate=0.25, classes=("poison_lane",)),
        clock, fault_wall_s=900.0)
    srv = StudyServer(ServeConfig(coalesce=True, audit_fraction=1.0,
                                  seed=seed),
                      clock=clock, chaos=monkey)
    for _ in range(8):
        srv.submit(SPEC_A, deadline_s=1e9)
    out = srv.drain()
    assert any(r.status == QUARANTINED for r in out)  # a real storm
    # A healthy follow-up at the DEFAULT deadline (300 s << the storm's
    # accumulated bisection wall) must be admitted and served.
    monkey.exempt.add(8)
    rid = srv.submit(SPEC_A)
    assert isinstance(rid, int), f"healthy follow-up shed: {rid}"
    (resp,) = srv.drain()
    assert resp.status == OK
    # ...and the now-observed healthy service time keeps admitting.
    monkey.exempt.add(9)
    rid2 = srv.submit(SPEC_A)
    assert isinstance(rid2, int), f"second follow-up shed: {rid2}"
    (resp2,) = srv.drain()
    assert resp2.status == OK


# -- blessed widths: warm manifest + compile-key reuse -----------------------


def test_blessed_width_warm_entries_and_zero_new_compiles(tmp_path):
    cfg = ServeConfig(cache_dir=str(tmp_path), default_deadline_s=1e9,
                      coalesce=True, audit_fraction=0.0)
    srv = StudyServer(cfg, clock=VirtualClock())
    for _ in range(3):  # 3 lanes -> blessed width 4
        srv.submit(SPEC_A)
    assert all(r.status == OK for r in srv.drain())
    entries = srv.warm.load_manifest()
    assert {e["lanes"] for e in entries} == {4}
    assert all(e["lanes"] in BLESSED_LANE_WIDTHS for e in entries)

    # Process death: in-process jit caches vanish; manifest + persistent
    # compile cache survive.  The restarted server re-warms the blessed
    # widths and re-serves the same coalesced shape with zero new scan
    # compiles.
    _engine._sweep_fn.cache_clear()
    srv2, replayed = restart_server(cfg, clock=VirtualClock())
    assert replayed == []
    before = dict(_engine.sweep_cache_sizes())
    for _ in range(3):
        srv2.submit(SPEC_A)
    out = srv2.drain()
    after = dict(_engine.sweep_cache_sizes())
    assert all(r.status == OK and r.engine == "coalesced" for r in out)
    assert after == before  # blessed-width keys were all re-warmed


# -- deadline accounting at admission ----------------------------------------


def test_request_that_would_expire_while_queued_sheds_at_admission():
    srv = _server(coalesce=False)
    srv._service_ema = 10.0  # measured: ~10 s of service per request
    assert isinstance(srv.submit(SPEC_A, deadline_s=1e9), int)
    # Two requests ahead -> ~30 s to completion; a 5 s deadline cannot be
    # met, so the request sheds now instead of timing out after dispatch.
    resp = srv.submit(SPEC_A, deadline_s=5.0)
    assert resp.status == "rejected_overload"
    assert "would expire while queued" in resp.error
    # A deadline the queue can meet is admitted.
    assert isinstance(srv.submit(SPEC_A, deadline_s=60.0), int)


# -- persistence hardening (schema versions + quarantine-and-rebuild) --------


def test_corrupt_warm_manifest_quarantined_not_wedging_restart(tmp_path):
    cfg = ServeConfig(cache_dir=str(tmp_path), default_deadline_s=1e9)
    srv = StudyServer(cfg, clock=VirtualClock())
    srv.submit(SPEC_A)
    assert srv.drain()[0].status == OK
    manifest = srv.warm.manifest_path
    manifest.write_text(manifest.read_text()[:40])  # torn write

    srv2, replayed = restart_server(cfg, clock=VirtualClock())
    assert replayed == []
    assert srv2.warm.quarantined_manifests == 1
    assert (tmp_path / "warm_manifest.json.corrupt-0").exists()
    assert not manifest.exists()  # rebuilt from empty on next record
    assert srv2.submit(SPEC_A) == 0 or True
    assert srv2.drain()[0].status == OK
    assert len(srv2.warm.load_manifest()) == 2  # rebuilt


def test_wrong_manifest_schema_version_quarantined(tmp_path):
    cfg = ServeConfig(cache_dir=str(tmp_path), default_deadline_s=1e9)
    srv = StudyServer(cfg, clock=VirtualClock())
    srv.warm.manifest_path.write_text(json.dumps(
        {"schema_version": 999, "entries": []}))
    assert srv.warm.load_manifest() == []
    assert srv.warm.quarantined_manifests == 1


def test_corrupt_journal_quarantined_not_wedging_restart(tmp_path):
    cfg = ServeConfig(cache_dir=str(tmp_path), default_deadline_s=1e9)
    (tmp_path / "journal.json").write_text('{"next_rid": 3, "inflight"')
    srv, replayed = restart_server(cfg, clock=VirtualClock())
    assert replayed == []
    assert srv.stats["quarantined_journals"] == 1
    assert (tmp_path / "journal.json.corrupt-0").exists()
    assert isinstance(srv.submit(SPEC_A), int)
    assert srv.drain()[0].status == OK


def test_resultset_load_json_raises_named_schema_errors(tmp_path):
    rs = build_study(SPEC_A).run("sequential")
    path = rs.save_json(tmp_path / "rs.json")
    loaded = ResultSet.load_json(path)
    _assert_rows_equal(loaded, rs)

    torn = tmp_path / "torn.json"
    torn.write_text(path.read_text()[:25])
    with pytest.raises(ResultSetSchemaError, match="truncated or corrupt"):
        ResultSet.load_json(torn)

    payload = json.loads(path.read_text())
    payload["schema_version"] = 999
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(payload))
    with pytest.raises(ResultSetSchemaError, match="schema_version"):
        ResultSet.load_json(bad)

    # Pre-stamp goldens (no version field) are version 1: must load.
    payload = json.loads(path.read_text())
    del payload["schema_version"]
    legacy = tmp_path / "legacy.json"
    legacy.write_text(json.dumps(payload))
    _assert_rows_equal(ResultSet.load_json(legacy), rs)

    mangled = tmp_path / "mangled.json"
    payload = json.loads(path.read_text())
    del payload["points"][0]["results"]
    mangled.write_text(json.dumps(payload))
    with pytest.raises(ResultSetSchemaError, match="malformed"):
        ResultSet.load_json(mangled)
