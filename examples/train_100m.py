"""End-to-end training driver: train a ~100M-param qwen3-family model for a
few hundred steps on the synthetic pipeline, with checkpointing and an
injected failure + restart (deliverable b).

    PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""

import argparse
import sys
import tempfile

import jax

sys.path.insert(0, "src")

from repro.configs import get_smoke_config          # noqa: E402
from repro.launch import train as train_mod          # noqa: E402
from repro.models.common import ModelConfig          # noqa: E402


def model_100m() -> ModelConfig:
    # ~100M params: 12L x d512 x ff2048, 16k vocab, qwen3-style qk-norm GQA
    return ModelConfig(
        name="qwen3-100m", family="dense", num_layers=12, d_model=512,
        num_heads=8, num_kv_heads=4, head_dim=64, d_ff=2048,
        vocab_size=16_384, qk_norm=True, remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    args_in = ap.parse_args()

    # route through the production train loop with a custom config
    import repro.launch.train as T

    orig_build = T.build

    def build_override(args):
        cfg = model_100m()
        from repro.models.model import Model
        from repro.optim import adamw
        model = Model(cfg)
        print(f"params: {model.param_count()/1e6:.1f}M")
        opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                    warmup_steps=10)
        step = jax.jit(T.steps_lib.make_train_step(model, opt_cfg))
        return cfg, model, opt_cfg, step

    T.build = build_override
    try:
        with tempfile.TemporaryDirectory() as d:
            args = argparse.Namespace(
                arch="qwen3-4b", smoke=True, steps=args_in.steps,
                batch=args_in.batch, seq=args_in.seq, lr=3e-3, seed=0,
                log_every=20, ckpt_dir=d, ckpt_every=50,
                fail_at=args_in.steps // 2)
            out = T.run(args)
            print(f"loss: {out['first_loss']:.3f} -> {out['last_loss']:.3f}")
            assert out["last_loss"] < out["first_loss"]
    finally:
        T.build = orig_build


if __name__ == "__main__":
    main()
