"""Study-planner tour: a hardware grid × lazy-knob ablation in one spec.

Sweeps the off-chip link bandwidth (the paper's scarce resource) against a
PIM-DBI on/off ablation on one graph workload, printing the planner's
predicted compile budget *before* running, then the pivoted result table.
The whole 3x2 cross-product costs one XLA compile per (mechanism, bucket).

    PYTHONPATH=src python examples/study_grid.py
"""

from repro.api import LazyPIMConfig, Study, grid


def main():
    study = Study(
        workloads=["pagerank-arxiv"],
        hw=grid(offchip_bw_gbs=[16.0, 32.0, 64.0]),
        mechanisms=("cpu", "cg", "lazypim"),
        lazy=[LazyPIMConfig(use_dbi=True), LazyPIMConfig(use_dbi=False)],
    )
    print(study.plan().describe())

    results = study.run()
    table = results.pivot(("hw_index", "lazy_index"), "mechanism", "speedup")
    bws = [h.offchip_bw_gbs for h in study.hw_points()]
    print(f"\n{'bw_gbs':>7s} {'dbi':>5s} {'cg':>7s} {'lazypim':>8s}")
    for (h, li), row in sorted(table.items()):
        dbi = study.lazy_points()[li].use_dbi
        print(f"{bws[h]:7.0f} {str(bool(dbi)):>5s} {row['cg']:7.2f} "
              f"{row['lazypim']:8.2f}")
    lz = [p for p in results.points if p.hw_index == 0]
    d_on, d_off = (p.results["lazypim"].dbi_writebacks for p in lz)
    print(f"\nDBI writebacks at 16 GB/s: {d_on:.0f} (on) vs {d_off:.0f} (off)")


if __name__ == "__main__":
    main()
