"""Serving examples: continuous token batching (deliverable b) and the
resilient resident study service.

    PYTHONPATH=src python examples/serve_batched.py

Part 1 drives the continuous-batching token loop.  Part 2 stands up a
:class:`repro.serve.StudyServer` with 25% injected chaos faults and shows
every fault class resolving explicitly — reject, retry-success, degrade to
the bit-exact sequential engine, or crash-then-warm-restart — with zero
wrong results.
"""

import argparse
import sys
import tempfile

sys.path.insert(0, "src")

from repro.launch.serve import serve  # noqa: E402
from repro.serve import (  # noqa: E402
    ChaosConfig,
    ChaosMonkey,
    ServeConfig,
    StudyServer,
    make_storm,
    restart_server,
)

SMALL = dict(num_kernels=3, windows_per_kernel=2)
SPECS = [
    {"workloads": [{"app": "pagerank", "graph": "arxiv", "scale": 0.4,
                    **SMALL}],
     "mechanisms": ["cpu", "cg", "lazypim"], "threads": 16},
    {"workloads": [{"app": "htap128", "scale": 0.004, **SMALL}],
     "mechanisms": ["cpu", "cg", "lazypim"], "threads": 16},
]


def token_demo():
    args = argparse.Namespace(arch="qwen3-4b", smoke=True, requests=6,
                              batch=3, max_new=8, max_len=48, seed=0)
    served = serve(args)
    for r in served:
        print(f"req {r.rid}: prompt {len(r.prompt)} toks -> "
              f"{len(r.out) - len(r.prompt)} new toks")
    assert len(served) == args.requests


def study_service_demo():
    cache_dir = tempfile.mkdtemp(prefix="repro-serve-demo-")
    monkey = ChaosMonkey(ChaosConfig(seed=2, fault_rate=0.25, hang_s=5.0))
    cfg = ServeConfig(default_deadline_s=120.0, heartbeat_timeout_s=2.0,
                      backoff_base_s=0.01, max_lanes=64,
                      cache_dir=cache_dir)
    server = StudyServer(cfg, chaos=monkey)
    monkey.clock = server.clock

    final = {}
    for spec in make_storm(monkey, 12, SPECS):
        out = server.submit(spec)
        if not isinstance(out, int):
            final[out.rid] = out
    for r in server.drain():
        final[r.rid] = r
    while server.crashed:
        print("worker crashed — restarting from the warm compile cache")
        server, replayed = restart_server(cfg, chaos=monkey)
        for r in [*replayed, *server.drain()]:
            final[r.rid] = r

    for rid in sorted(final):
        r = final[rid]
        mark = " (recovered after crash)" if r.restarted else ""
        print(f"study req {rid}: {r.status} engine={r.engine} "
              f"attempts={r.attempts}{mark}")
    assert all(r.status != "crashed" for r in final.values())
    print(f"chaos injected: {monkey.injected or 'nothing'}")


def main():
    print("== continuous token batching ==")
    token_demo()
    print("\n== resident study service under chaos ==")
    study_service_demo()


if __name__ == "__main__":
    main()
