"""Batched serving example: continuous batching over a request queue
(deliverable b).

    PYTHONPATH=src python examples/serve_batched.py
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.serve import serve  # noqa: E402


def main():
    args = argparse.Namespace(arch="qwen3-4b", smoke=True, requests=6,
                              batch=3, max_new=8, max_len=48, seed=0)
    served = serve(args)
    for r in served:
        print(f"req {r.rid}: prompt {len(r.prompt)} toks -> "
              f"{len(r.out) - len(r.prompt)} new toks")
    assert len(served) == args.requests


if __name__ == "__main__":
    main()
