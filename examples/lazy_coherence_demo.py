"""LazySync demo: the paper's coherence protocol driving sparse embedding
sync across 4 data-parallel groups, vs dense all-reduce (beyond-paper).

    PYTHONPATH=src python examples/lazy_coherence_demo.py
"""

import sys

sys.path.insert(0, "src")

import jax                                    # noqa: E402
import jax.numpy as jnp                       # noqa: E402

from repro.configs import get_smoke_config    # noqa: E402
from repro.core.lazy_sync import (LazyEmbed, LazySyncConfig,  # noqa: E402
                                  init_state)


def main():
    mcfg = get_smoke_config("qwen3_4b")
    cfg = LazySyncConfig(num_groups=4, commit_interval=8,
                         max_reconcile_rows=128)
    emb = LazyEmbed(mcfg, cfg)
    params = emb.init(jax.random.key(0))
    state = init_state(cfg, mcfg.vocab)

    key = jax.random.key(1)
    tot_lazy = tot_dense = 0.0
    for step in range(24):
        key, k1, k2 = jax.random.split(key, 3)
        # each group touches a sparse, partly-overlapping row set
        touched = jax.random.randint(k1, (cfg.num_groups, 48), 0,
                                     mcfg.vocab // 4, dtype=jnp.int32)
        g = jax.random.normal(k2, touched.shape + (mcfg.d_model,)) * 0.05
        grads = jnp.zeros((cfg.num_groups, mcfg.vocab, mcfg.d_model))
        grads = grads.at[jnp.arange(cfg.num_groups)[:, None], touched].add(g)
        params, state, m = emb.sync_step(params, state, touched, grads)
        tot_lazy += float(m["lazy_bytes"])
        tot_dense += float(m["dense_bytes"])
        if step % 8 == 7:
            print(f"step {step}: conflicts={int(m['lazy_conflict_rows'])} "
                  f"commit={bool(m['lazy_commit'])} "
                  f"lazy={float(m['lazy_bytes'])/1e3:.1f}KB "
                  f"dense={float(m['dense_bytes'])/1e3:.1f}KB")
    print(f"\ntotal coherence bytes: LazySync {tot_lazy/1e6:.2f}MB vs "
          f"dense {tot_dense/1e6:.2f}MB  ({1-tot_lazy/tot_dense:.1%} saved)")


if __name__ == "__main__":
    main()
