"""Quickstart: the paper in 60 seconds.

One declarative ``Study`` runs the LazyPIM coherence simulator on a graph
workload + an HTAP workload (every mechanism, bucketed single-compile
planner) and prints the speedup/traffic/energy table, then exercises the
Bloom-signature kernel the protocol is built on.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp

from repro.api import Study
from repro.core.signatures import SignatureSpec, empty_signature
from repro.kernels.bloom import bloom_insert, bloom_intersect


def main():
    results = Study(workloads=["pagerank-arxiv", "htap128"]).run()
    for point, summary in zip(results.points, results.normalized()):
        print(f"\n== {point.workload} (normalized to CPU-only) ==")
        print(f"{'mechanism':10s} {'speedup':>8s} {'traffic':>8s} {'energy':>8s}")
        for m in ("fg", "cg", "nc", "lazypim", "ideal"):
            d = summary[m]
            print(f"{m:10s} {d['speedup']:8.2f} {d['traffic']:8.2f} {d['energy']:8.2f}")
        lz = summary["lazypim"]
        print(f"LazyPIM conflict rate: {lz['conflict_rate']:.1%} "
              f"(exact {lz['conflict_rate_exact']:.1%})")

    # the coherence signatures themselves
    spec = SignatureSpec()
    pim_reads = bloom_insert(spec, empty_signature(spec),
                             jnp.arange(100, 200, dtype=jnp.uint32))
    cpu_writes = bloom_insert(spec, empty_signature(spec),
                              jnp.asarray([150], jnp.uint32))
    clean = bloom_insert(spec, empty_signature(spec),
                         jnp.asarray([5000], jnp.uint32))
    print(f"\nsignature conflict (overlapping sets): "
          f"{bool(bloom_intersect(spec, pim_reads[None], cpu_writes[None])[0])}")
    print(f"signature conflict (disjoint sets):     "
          f"{bool(bloom_intersect(spec, pim_reads[None], clean[None])[0])}")


if __name__ == "__main__":
    main()
